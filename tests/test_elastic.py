"""Elastic pool tests: incremental point extension, plan grow/shrink,
mesh planning, policy/monitor resize, panel-cache seeding, and ladder
respecialisation (DESIGN.md Sec. 12).

The load-bearing invariant throughout: growing a pool APPENDS — the
first K evaluation points, encode-coefficient rows, decode panels, and
compiled executables are bit-identical before and after, so surviving
workers' tasks never move and nothing recompiles.
"""
import numpy as np
import pytest

import jax
import jax.numpy as jnp

jax.config.update("jax_enable_x64", True)

from repro.core.api import extend_plan, make_plan, shrink_plan, uncoded_matmul  # noqa: E402
from repro.core.bounds import conservative_L  # noqa: E402
from repro.core.points import POINT_KINDS, extend_points, make_points  # noqa: E402
from repro.core.schemes import make_scheme  # noqa: E402
from repro.distributed.elastic import CodedElasticPolicy, plan_shrink  # noqa: E402

try:  # CI installs hypothesis; the tests fall back to an exhaustive
    from hypothesis import given, settings, strategies as st  # noqa: E402
    HAVE_HYPOTHESIS = True
except ImportError:  # pragma: no cover - exercised only without hypothesis
    HAVE_HYPOTHESIS = False


# ---------------------------------------------------------------------------
# extend_points: property checks (shared by the deterministic sweep and the
# hypothesis fuzzers below)
# ---------------------------------------------------------------------------


def _check_prefix_and_distinct(kind, K, g):
    z = make_points(kind, K)
    ext = extend_points(z, g)
    assert ext.shape == (K + g,)
    # prefix is the SAME bits, not merely close
    np.testing.assert_array_equal(ext[:K], z)
    d = np.abs(ext[:, None] - ext[None, :])
    np.fill_diagonal(d, np.inf)
    assert d.min() > 1e-12


def _check_conditioning(kind, K, g):
    ext = extend_points(make_points(kind, K), g)
    V = np.vander(ext, increasing=True)
    cond = np.linalg.cond(V)
    assert np.isfinite(cond) and cond < 1e12


def _check_dtype_preserved(K, g, dtype):
    z = make_points("chebyshev", K, dtype=dtype)
    ext = extend_points(z, g)
    assert ext.dtype == z.dtype
    zc = make_points("unit_circle", K, dtype=dtype)
    extc = extend_points(zc, g)
    assert extc.dtype == zc.dtype
    assert np.iscomplexobj(extc)
    # complex extensions stay on the unit circle (Leja candidates)
    tol = 1e-6 if dtype == np.float32 else 1e-12
    np.testing.assert_allclose(np.abs(extc[K:]), 1.0, rtol=tol)


class TestExtendPointsProperties:
    """Exhaustive sweep over the small parameter box — always runs."""

    @pytest.mark.parametrize("kind", POINT_KINDS)
    @pytest.mark.parametrize("K", [2, 3, 5, 8])
    @pytest.mark.parametrize("g", [1, 2, 4])
    def test_prefix_bitexact_and_pairwise_distinct(self, kind, K, g):
        _check_prefix_and_distinct(kind, K, g)

    @pytest.mark.parametrize("kind", POINT_KINDS)
    @pytest.mark.parametrize("K,g", [(2, 1), (5, 3), (8, 4)])
    def test_extended_vandermonde_stays_conditioned(self, kind, K, g):
        _check_conditioning(kind, K, g)

    @pytest.mark.parametrize("dtype", [np.float32, np.float64])
    @pytest.mark.parametrize("K,g", [(2, 1), (6, 3)])
    def test_dtype_preserved(self, K, g, dtype):
        _check_dtype_preserved(K, g, dtype)

    def test_g_zero_returns_copy(self):
        z = make_points("chebyshev", 4)
        out = extend_points(z, 0)
        np.testing.assert_array_equal(out, z)
        assert out is not z

    def test_validation(self):
        z = make_points("chebyshev", 4)
        with pytest.raises(ValueError, match="g must be >= 0"):
            extend_points(z, -1)
        with pytest.raises(ValueError, match="1-D non-empty"):
            extend_points(np.empty((0,)), 2)
        with pytest.raises(ValueError, match="1-D non-empty"):
            extend_points(z.reshape(2, 2), 1)


if HAVE_HYPOTHESIS:

    class TestExtendPointsFuzz:
        """Hypothesis widens the sweep to the whole (kind, K, g, dtype) box."""

        @settings(max_examples=40, deadline=None)
        @given(kind=st.sampled_from(POINT_KINDS),
               K=st.integers(2, 10), g=st.integers(1, 5))
        def test_prefix_bitexact_and_pairwise_distinct(self, kind, K, g):
            _check_prefix_and_distinct(kind, K, g)

        @settings(max_examples=40, deadline=None)
        @given(kind=st.sampled_from(POINT_KINDS),
               K=st.integers(2, 10), g=st.integers(1, 5))
        def test_extended_vandermonde_stays_conditioned(self, kind, K, g):
            _check_conditioning(kind, K, g)

        @settings(max_examples=25, deadline=None)
        @given(K=st.integers(2, 8), g=st.integers(1, 4),
               dtype=st.sampled_from([np.float32, np.float64]))
        def test_dtype_preserved(self, K, g, dtype):
            _check_dtype_preserved(K, g, dtype)

        @settings(max_examples=15, deadline=None)
        @given(kind=st.sampled_from(POINT_KINDS), K=st.integers(2, 8),
               g1=st.integers(1, 3), g2=st.integers(1, 3))
        def test_extension_composes(self, kind, K, g1, g2):
            """Extending twice gives a prefix-compatible superset of
            extending once: old points never move, whatever the path."""
            z = make_points(kind, K)
            once = extend_points(z, g1)
            twice = extend_points(once, g2)
            np.testing.assert_array_equal(twice[:K + g1], once)
            np.testing.assert_array_equal(twice[:K], z)


# ---------------------------------------------------------------------------
# extend_plan / shrink_plan: survivors' rows are untouched
# ---------------------------------------------------------------------------

_SCHEME_CASES = [
    # (kind, p_prime); grid p=2, m=2, n=2 throughout
    ("bec", 1),        # tau = m*n = 4
    ("tradeoff", 2),   # tau = m*n*p' + p' - 1 = 9
    ("polycode", 1),   # tau = p*m*n + p - 1 = 9
]


def _small_plan(kind, p_prime, K, L=2000, points="chebyshev"):
    return make_plan(kind, 2, 2, 2, K=K, L=L,
                     p_prime=p_prime, points=points)


class TestExtendPlanParity:
    @pytest.mark.parametrize("kind,pp", _SCHEME_CASES)
    @pytest.mark.parametrize("g", [1, 2, 3])
    def test_incremental_rows_match_fresh_build(self, kind, pp, g):
        """extend_plan == make_plan at K+g with the same points, bit-exact."""
        plan = _small_plan(kind, pp, K=10)
        ext = extend_plan(plan, g)
        fresh = make_plan(kind, 2, 2, 2, K=10 + g, L=2000, p_prime=pp,
                          z_points=ext.z_points)
        np.testing.assert_array_equal(ext.coeff_a, fresh.coeff_a)
        np.testing.assert_array_equal(ext.coeff_b, fresh.coeff_b)
        # and the first K rows are plan's rows, by value AND identity
        np.testing.assert_array_equal(ext.coeff_a[:10], plan.coeff_a)
        assert ext.s == plan.s and ext.scheme is plan.scheme

    def test_g_zero_is_identity(self):
        plan = _small_plan("bec", 1, K=6)
        assert extend_plan(plan, 0) is plan

    def test_z_new_must_extend_prefix(self):
        plan = _small_plan("bec", 1, K=6)
        bad = np.concatenate([plan.z_points[::-1], [0.123]])
        with pytest.raises(ValueError, match="must extend the plan's"):
            extend_plan(plan, 1, z_new=bad)

    @pytest.mark.parametrize("kind,pp", _SCHEME_CASES)
    @pytest.mark.parametrize("backend", ["reference", "fused"])
    def test_decode_after_extend_bit_identical(self, kind, pp, backend):
        """Extended-plan decode == fresh-plan decode, bit for bit, and both
        are exact — across schemes x backends (the satellite's core claim)."""
        from repro.runtime import CodedMatmul

        rng = np.random.default_rng(11)
        v, r, t = 8, 4, 4
        A = jnp.asarray(rng.integers(-3, 4, size=(v, r)), jnp.float64)
        B = jnp.asarray(rng.integers(-3, 4, size=(v, t)), jnp.float64)
        L = conservative_L(v, 3, 3)
        K, g = 10, 2
        plan = make_plan(kind, 2, 2, 2, K=K, L=L, p_prime=pp,
                         points="chebyshev")
        ext = extend_plan(plan, g)
        fresh = make_plan(kind, 2, 2, 2, K=K + g, L=L, p_prime=pp,
                          z_points=ext.z_points)
        cm_ext = CodedMatmul(ext, backend, dtype=jnp.float64)
        cm_fresh = CodedMatmul(fresh, backend, dtype=jnp.float64)
        truth = np.asarray(uncoded_matmul(A, B))
        # erase some OLD workers: the joiners' fresh points carry the decode
        erased = [1, 3]
        c_ext = np.asarray(cm_ext(A, B, erased=erased))
        c_fresh = np.asarray(cm_fresh(A, B, erased=erased))
        np.testing.assert_array_equal(c_ext, c_fresh)
        np.testing.assert_array_equal(c_ext, truth)
        # erase the JOINERS: the grown pool degrades to the old pool
        c_old = np.asarray(cm_ext(A, B, erased=list(range(K, K + g))))
        np.testing.assert_array_equal(c_old, truth)


class TestShrinkPlan:
    def test_survivor_rows_are_slices(self):
        plan = _small_plan("bec", 1, K=8)
        keep = [0, 2, 5, 6, 7]
        small = shrink_plan(plan, keep)
        assert small.K == 5
        np.testing.assert_array_equal(small.z_points, plan.z_points[keep])
        np.testing.assert_array_equal(small.coeff_a, plan.coeff_a[keep])
        np.testing.assert_array_equal(small.coeff_b, plan.coeff_b[keep])

    def test_validation(self):
        plan = _small_plan("bec", 1, K=8)  # tau = 4
        with pytest.raises(ValueError, match="duplicate-free"):
            shrink_plan(plan, [0, 1, 1, 2])
        with pytest.raises(ValueError, match="outside the pool"):
            shrink_plan(plan, [0, 1, 2, 99])
        with pytest.raises(ValueError, match="breaks tau=4"):
            shrink_plan(plan, [0, 1, 2])


# ---------------------------------------------------------------------------
# plan_shrink: mesh-selection edges (satellite 4)
# ---------------------------------------------------------------------------


class TestPlanShrinkEdges:
    def test_exact_fit_takes_full_mesh(self):
        assert plan_shrink(8) == (2, 4)
        assert plan_shrink(256) == (16, 16)

    def test_inexact_fit_rounds_down(self):
        assert plan_shrink(7) == (2, 2)
        assert plan_shrink(255) == (8, 16)

    def test_single_device(self):
        assert plan_shrink(1) == (1, 1)

    def test_zero_devices_raises(self):
        with pytest.raises(ValueError, match="no supported mesh fits 0"):
            plan_shrink(0)


# ---------------------------------------------------------------------------
# CodedElasticPolicy: observe_mask edges + elastic shrink/grow (satellite 4)
# ---------------------------------------------------------------------------


class TestCodedElasticPolicy:
    @pytest.mark.parametrize("mask", [
        [1, 0, 1, 1, 0, 1],
        np.array([1, 0, 1, 1, 0, 1], dtype=bool),
        np.array([1.0, 0.0, 0.5, 2.0, 0.0, 1.0]),  # any nonzero = healthy
        np.array([1, 0, 1, 1, 0, 1], dtype=np.int32),
    ])
    def test_observe_mask_accepts_int_bool_float(self, mask):
        pol = CodedElasticPolicy(K=6, tau=3)
        pol.observe_mask(mask)
        assert pol.healthy.dtype == bool
        assert int(pol.healthy.sum()) == 4
        assert pol.slack == 1 and not pol.must_respecialize

    def test_observe_mask_shape_mismatch(self):
        pol = CodedElasticPolicy(K=6, tau=3)
        with pytest.raises(ValueError, match=r"mask shape \(5,\) != \(6,\)"):
            pol.observe_mask(np.ones(5))
        with pytest.raises(ValueError, match="mask shape"):
            pol.observe_mask(np.ones((2, 3)))

    def test_slack_and_respecialize_trigger(self):
        pol = CodedElasticPolicy(K=6, tau=4)
        assert pol.slack == 2
        pol.mark_failed(1)
        pol.mark_failed(4)
        assert pol.slack == 0 and pol.must_respecialize
        pol.mark_recovered(4)
        assert not pol.must_respecialize

    def test_shrink_compacts_health_bits(self):
        pol = CodedElasticPolicy(K=6, tau=2)
        pol.observe_mask([1, 0, 1, 1, 0, 1])
        pol.shrink([0, 2, 3, 5])
        assert pol.K == 4
        np.testing.assert_array_equal(pol.healthy, [1, 1, 1, 1])

    def test_grow_appends_healthy(self):
        pol = CodedElasticPolicy(K=4, tau=2)
        pol.mark_failed(3)
        pol.grow(2)
        assert pol.K == 6
        np.testing.assert_array_equal(pol.healthy, [1, 1, 1, 0, 1, 1])
        with pytest.raises(ValueError, match="g must be >= 0"):
            pol.grow(-1)

    def test_shrink_validation(self):
        pol = CodedElasticPolicy(K=4, tau=2)
        with pytest.raises(ValueError, match="1-D and non-empty"):
            pol.shrink([])
        with pytest.raises(ValueError, match="duplicate"):
            pol.shrink([0, 0, 1])
        with pytest.raises(ValueError, match="outside the pool of 4"):
            pol.shrink([0, 9])


# ---------------------------------------------------------------------------
# WorkerHealthMonitor.resize: state carries across a handoff
# ---------------------------------------------------------------------------


class TestMonitorResize:
    def _warm_monitor(self):
        from repro.control.monitor import WorkerHealthMonitor
        mon = WorkerHealthMonitor(4, alpha=1.0, min_history=1)
        mon.record_step([1.0, 2.0, 3.0, 40.0])
        mon.record_step([1.0, 2.0, 3.0, 40.0])
        return mon

    def test_shrink_carries_survivor_state(self):
        mon = self._warm_monitor()
        score_before = mon.straggler_scores().copy()
        mon.resize(keep=[0, 2])
        assert mon.K == 2 and mon.steps == 2  # steps NOT reset
        np.testing.assert_allclose(mon.mean, [1.0, 3.0])
        np.testing.assert_allclose(
            mon.straggler_scores(), score_before[[0, 2]])

    def test_grow_fills_with_survivor_average(self):
        mon = self._warm_monitor()
        mon.resize(keep=[0, 1, 2], grow=2)
        assert mon.K == 5
        np.testing.assert_allclose(mon.mean, [1.0, 2.0, 3.0, 2.0, 2.0])
        np.testing.assert_allclose(mon.straggler_scores()[3:], 0.0)

    def test_resize_validation(self):
        mon = self._warm_monitor()
        with pytest.raises(ValueError, match="duplicate-free"):
            mon.resize(keep=[0, 0])
        with pytest.raises(ValueError, match="outside the pool of 4"):
            mon.resize(keep=[0, 7])
        with pytest.raises(ValueError, match="grow must be >= 0"):
            mon.resize(grow=-1)
        with pytest.raises(ValueError, match="empty pool"):
            mon.resize(keep=[])


# ---------------------------------------------------------------------------
# DecodePanelCache.extended: grow seeds panels, zero refactorisations
# ---------------------------------------------------------------------------


class TestPanelCacheExtension:
    def _cache(self, K=6):
        from repro.core.decoding import DecodePanelCache
        scheme = make_scheme("bec", 2, 2, 2)  # tau = 4
        z = make_points("chebyshev", K)
        return DecodePanelCache(scheme, z), z

    def test_seeded_panels_cost_zero_builds(self):
        cache, z = self._cache()
        mask = np.array([1, 1, 0, 1, 1, 0], dtype=np.float64)
        old = cache.get(mask)
        assert cache.builds == 1
        ext = cache.extended(extend_points(z, 2))
        assert ext.builds == 0
        # the K-pool pattern, seen from the grown pool (joiners erased)
        panel = ext.get(np.concatenate([mask, np.zeros(2)]))
        assert ext.builds == 0  # a seed, not a refactorisation
        np.testing.assert_array_equal(panel.W[:, :6], old.W)
        np.testing.assert_array_equal(panel.W[:, 6:], 0.0)

    def test_fresh_patterns_still_build(self):
        cache, z = self._cache()
        ext = cache.extended(extend_points(z, 2))
        ext.get(np.array([1, 1, 1, 0, 1, 0, 1, 1], dtype=np.float64))
        assert ext.builds == 1

    def test_prefix_must_be_bitexact(self):
        cache, z = self._cache()
        with pytest.raises(ValueError, match="bit-exact prefix"):
            cache.extended(np.concatenate([z + 1e-9, [0.05, -0.05]]))
        with pytest.raises(ValueError, match="bit-exact prefix"):
            cache.extended(z[:4])


# ---------------------------------------------------------------------------
# PlanLadder.respecialize: re-lower on shrink, extend-and-seed on grow
# ---------------------------------------------------------------------------


class TestLadderRespecialize:
    def _ladder(self, K=6):
        from repro.control.ladder import PlanLadder
        # grid (2,2,1): bec tau=2; tradeoff/polycode tau=5
        return PlanLadder(2, 2, 1, K=K, L=conservative_L(8, 3, 3),
                          backend="reference", dtype=jnp.float64)

    def test_shrink_relowers_onto_survivors(self):
        ladder = self._ladder()
        ladder.prewarm((8, 4), (8, 2))
        group = ladder.group
        keys_before = set(group.executables)
        assert keys_before
        taus = {r: ladder.tau(r) for r in ladder.rungs}
        wide = max(taus, key=taus.get)
        ladder.switch(wide)
        keep = np.asarray([0, 2, 4])  # 3 survivors: only the tau<=3 rung fits
        info = ladder.respecialize(ladder.z_points[keep])
        assert ladder.K == 3
        assert ladder.tau(ladder.active) <= 3 and ladder.active != wide
        np.testing.assert_array_equal(
            np.asarray(ladder.plan(ladder.active).z_points),
            np.asarray(self._ladder().z_points[keep]))
        # same CacheGroup, and the old pool's executables are still there
        assert ladder.group is group
        assert keys_before <= set(group.executables)
        assert isinstance(info, dict)

    def test_shrink_below_every_tau_raises(self):
        ladder = self._ladder()
        with pytest.raises(ValueError, match="no rung of grid"):
            ladder.respecialize(ladder.z_points[:1])  # min tau is 2

    def test_grow_extends_points_and_keeps_executables(self):
        ladder = self._ladder()
        ladder.prewarm((8, 4), (8, 2))
        group = ladder.group
        keys_before = set(group.executables)
        z_ext = extend_points(ladder.z_points, 2)
        ladder.respecialize(z_ext)
        assert ladder.K == 8
        np.testing.assert_array_equal(ladder.z_points, z_ext)
        for rung in ladder.rungs:
            np.testing.assert_array_equal(
                ladder.plan(rung).z_points, z_ext)
        assert ladder.group is group
        assert keys_before <= set(group.executables)
        # old-pool erasure patterns were SEEDED into the grown caches by
        # zero-column padding: querying one costs no new factorisation
        pc = ladder.facade(ladder.active).panel_cache
        builds = pc.builds
        pc.get(np.concatenate([np.ones(6), np.zeros(2)]))
        assert pc.builds == builds

    def test_respecialize_validates_points(self):
        ladder = self._ladder()
        with pytest.raises(ValueError):
            ladder.respecialize(np.empty((0,)))
        with pytest.raises(ValueError):
            ladder.respecialize(ladder.z_points.reshape(2, 3))

    def test_grown_ladder_still_decodes_exactly(self):
        rng = np.random.default_rng(5)
        A = jnp.asarray(rng.integers(-3, 4, size=(8, 4)), jnp.float64)
        B = jnp.asarray(rng.integers(-3, 4, size=(8, 2)), jnp.float64)
        ladder = self._ladder()
        truth = np.asarray(uncoded_matmul(A, B))
        np.testing.assert_array_equal(np.asarray(ladder(A, B)), truth)
        ladder.respecialize(extend_points(ladder.z_points, 2))
        # erase both joiners AND one veteran: decode still exact
        out = ladder(A, B, erased=[3, 6, 7])
        np.testing.assert_array_equal(np.asarray(out), truth)


# ---------------------------------------------------------------------------
# AdaptiveServer: elastic-mode validation
# ---------------------------------------------------------------------------


class TestServerElasticValidation:
    def _server(self, **kw):
        from repro.control.driver import AdaptiveServer
        from repro.control.policy import ExpectedLatencyPolicy
        ladder = TestLadderRespecialize()._ladder()
        width = kw.pop("width", 6)
        return AdaptiveServer(ladder, feed=lambda i: np.ones(width),
                              policy=ExpectedLatencyPolicy(ladder), **kw)

    def test_pool_requires_universe(self):
        with pytest.raises(ValueError, match="pool= requires universe="):
            self._server(pool=np.arange(6))

    def test_universe_smaller_than_pool(self):
        with pytest.raises(ValueError, match="smaller than the pool"):
            self._server(universe=4)

    def test_pool_members_validated(self):
        with pytest.raises(ValueError, match="distinct universe members"):
            self._server(universe=10, pool=[0, 0, 1, 2, 3, 4])
        with pytest.raises(ValueError, match="outside the universe"):
            self._server(universe=10, pool=[0, 1, 2, 3, 4, 99])

    def test_grow_needs_elastic_server(self):
        srv = self._server()
        with pytest.raises(ValueError, match="elastic server"):
            srv.grow([6])

    def test_grow_rejects_bad_joiners(self):
        srv = self._server(universe=10, width=10)
        with pytest.raises(ValueError, match="already in the pool"):
            srv.grow([0])
        with pytest.raises(ValueError, match="outside the universe"):
            srv.grow([42])
        with pytest.raises(ValueError, match="duplicate"):
            srv.grow([6, 6])
