"""End-to-end behaviour tests for the paper's system.

The full pipeline at paper geometry (m=n=p=2, K=10 workers, integer
matrices, equispaced points - paper Sec. V), asserting the headline claims:
exact decode under the maximum erasure budget, BEC's 6-straggler tolerance
vs the polynomial-code baseline's 1, and the latency-shape of Fig. 1.
"""
import numpy as np
import pytest

import jax

jax.config.update("jax_enable_x64", True)
import jax.numpy as jnp  # noqa: E402

from repro.core import (  # noqa: E402
    LatencyModel,
    coded_matmul,
    make_plan,
    simulate_completion,
    uncoded_matmul,
)


@pytest.fixture(scope="module")
def paper_setup():
    rng = np.random.default_rng(42)
    v = r = t = 256  # scaled-down Sec. V geometry
    A = jnp.asarray(rng.integers(0, 51, size=(v, r)), jnp.float64)
    B = jnp.asarray(rng.integers(0, 51, size=(v, t)), jnp.float64)
    L = v * 50 * 50 + 1
    return A, B, L


class TestPaperSystem:
    def test_bec_survives_six_stragglers(self, paper_setup):
        """The paper's headline: tau=4 of K=10 -> any 6 workers can die."""
        A, B, L = paper_setup
        plan = make_plan("bec", 2, 2, 2, K=10, L=L, points="unit_circle")
        assert plan.tau == 4
        C_ref = uncoded_matmul(A, B)
        rng = np.random.default_rng(0)
        for _ in range(3):
            dead = rng.choice(10, size=6, replace=False).tolist()
            C = coded_matmul(A, B, plan, erased=dead)
            np.testing.assert_allclose(np.asarray(C), np.asarray(C_ref),
                                       atol=1e-6)

    def test_polycode_needs_nine(self, paper_setup):
        A, B, L = paper_setup
        plan = make_plan("polycode", 2, 2, 2, K=10, L=L, points="unit_circle")
        assert plan.tau == 9
        C_ref = uncoded_matmul(A, B)
        C = coded_matmul(A, B, plan, erased=[5])  # 1 straggler ok
        np.testing.assert_allclose(np.asarray(C), np.asarray(C_ref), atol=1e-6)
        with pytest.raises(ValueError, match="undecodable"):
            coded_matmul(A, B, plan, erased=[0, 1])  # 2 stragglers fatal

    def test_fig1_latency_shape(self):
        """BEC flat to S=6 then jumps; polycode degrades from S=2."""
        model = LatencyModel(base=1.0, straggler_slowdown=2.0)
        bec = [float(np.median(simulate_completion(10, 4, S, model,
                                                   trials=30, seed=S)))
               for S in range(9)]
        poly = [float(np.median(simulate_completion(10, 9, S, model,
                                                    trials=30, seed=S)))
                for S in range(9)]
        assert bec[:7] == [1.0] * 7 and bec[7] == 2.0
        assert poly[0] == poly[1] == 1.0 and poly[2] == 2.0

    def test_end_to_end_float_workflow(self, paper_setup):
        """Floats via scale-and-round (paper footnote 1): quantised coded
        product matches the quantised reference exactly."""
        rng = np.random.default_rng(1)
        x = rng.normal(size=(128, 64))
        w = rng.normal(size=(128, 96))
        qmax = 31  # 6-bit grid
        sx = np.abs(x).max() / qmax
        sw = np.abs(w).max() / qmax
        xi, wi = np.round(x / sx), np.round(w / sw)
        L = 128 * qmax * qmax + 1
        plan = make_plan("bec", 2, 2, 2, K=8, L=L, points="unit_circle")
        C = coded_matmul(jnp.asarray(xi), jnp.asarray(wi), plan, erased=[0, 7])
        np.testing.assert_allclose(np.asarray(C), xi.T @ wi, atol=1e-6)
